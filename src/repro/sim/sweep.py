"""Batched fast-memory-size sweep engine (the offline database hot path).

Tuna's offline component executes the same micro-benchmark trace at ~21
fast-memory sizes (paper Sections 3.3/5). Running :func:`repro.sim.engine.
simulate` once per size repeats every size-independent computation — trace
iteration, LLC absorption, MLP estimation, and the whole hotness bookkeeping
— 21 times. This module simulates **one trace across the whole size vector
in a single pass**:

* page touches are trace-driven, so per-page heat and the interval touch
  counters are *identical at every size*: one shared
  :class:`~repro.tiering.page_pool.LazyHeat` and one shared dense touch
  array serve all sizes;
* only tier occupancy differs per size: it lives in one stacked
  ``[n_sizes, rss_pages]`` array, and each size's policy steps over a
  lightweight slice pool (:meth:`TieredPagePool._shared_slice`) that views
  its row — the *same* ``TPPPolicy`` code the per-size engine runs, so the
  sweep cannot drift semantically;
* per-interval tier classification of the touched pages is one batched
  ``[n_sizes, n_touched]`` gather instead of ``n_sizes`` passes.

Exactness: every per-size arithmetic sequence matches a standalone
``simulate(trace, fm_frac=f)`` bit for bit (integer counters; float times),
which ``tests/test_engine_equivalence.py`` asserts.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.trace import Trace
from repro.sim.costmodel import (
    HardwareProfile,
    OPTANE_LIKE,
    absorb_cache,
    effective_mlp,
    interval_time,
)
from repro.tiering.page_pool import (
    LazyGrankBox,
    LazyHeat,
    Tier,
    TieredPagePool,
)
from repro.tiering.policy import TPPPolicy


@dataclass
class SweepResult:
    """Per-size outcome of one batched sweep."""

    name: str
    fm_fracs: np.ndarray  # [n_sizes]
    interval_times: np.ndarray  # [n_sizes, n_intervals]
    stats: list  # final pool counter snapshot per size
    configs: list | None = None  # per size: ConfigVector per interval

    @property
    def total_times(self) -> np.ndarray:
        return self.interval_times.sum(axis=1)


def sweep_fm_fracs(
    trace: Trace,
    fm_fracs,
    hot_thr: int = 4,
    hw: HardwareProfile = OPTANE_LIKE,
    hw_capacity_pages: int | None = None,
    seed: int = 0,
    collect_configs: bool = False,
) -> SweepResult:
    """Run ``trace`` once, concurrently at every fraction in ``fm_fracs``.

    Equivalent to ``[simulate(trace, fm_frac=f, policy=TPPPolicy(hot_thr))
    for f in fm_fracs]`` (same counters, same interval times), at roughly
    the cost of the most expensive single size plus the per-size policy
    work.
    """
    fm_fracs = np.asarray(fm_fracs, dtype=np.float64)
    n_sizes = fm_fracs.size
    if n_sizes == 0:
        raise ValueError("sweep_fm_fracs needs at least one fm fraction")
    num_pages = int(trace.rss_pages)
    cap = int(hw_capacity_pages or trace.rss_pages)
    policy = TPPPolicy(hot_thr=hot_thr)

    # stacked per-size tier state + state shared across sizes
    tier_b = np.full((n_sizes, num_pages), int(Tier.UNALLOCATED), dtype=np.int8)
    halflife_decay = 0.5 ** (1.0 / 2.0)  # TieredPagePool default halflife
    heat = LazyHeat(num_pages, halflife_decay)
    interval_acc = np.zeros(num_pages, dtype=np.int64)
    interval_touch = np.zeros(num_pages, dtype=np.int64)
    pools = []
    for s in range(n_sizes):
        pool = TieredPagePool._shared_slice(
            tier_row=tier_b[s],
            heat=heat,
            interval_acc=interval_acc,
            interval_touch=interval_touch,
            hw_capacity=cap,
            page_bytes=hw.page_bytes,
            kswapd_batch=None,
            seed=seed,
        )
        pool.set_fm_size(int(round(fm_fracs[s] * cap)))
        if trace.slow_pages is not None:
            pool.place(trace.slow_pages, Tier.SLOW)
        pools.append(pool)

    n_intervals = len(trace)
    times = np.zeros((n_sizes, n_intervals), dtype=np.float64)
    fast_code = int(Tier.FAST)
    slow_code = int(Tier.SLOW)
    profilers = configs_out = None
    if collect_configs:
        from repro.core.telemetry import IntervalProfiler

        profilers = [
            IntervalProfiler(hot_thr=hot_thr, num_threads=trace.num_threads)
            for _ in range(n_sizes)
        ]
        configs_out = [[] for _ in range(n_sizes)]
    for i, ia in enumerate(trace):
        pages = ia.pages
        # --- size-independent work, computed once for all sizes
        counts_mem = absorb_cache(ia.counts, hw.llc_pages)
        mlp_eff = effective_mlp(counts_mem, hw.mlp, trace.num_threads)
        new_mask = tier_b[0, pages] == Tier.UNALLOCATED
        new_pages = pages[new_mask] if bool(new_mask.any()) else None
        for pool in pools:
            pool._grank_box = None  # new touches change the ranking
            if new_pages is not None:
                pool._first_touch_alloc(new_pages)
        interval_touch[pages] += ia.touches
        # one stable ranking of every page by (effective heat, id) serves
        # the victim selection of all sizes this interval — materialized
        # lazily, since demotion-free intervals never need it
        grank_box = LazyGrankBox(heat, interval_touch)
        for pool in pools:
            pool._grank_box = grank_box
            pool._gptr = 0
        # --- batched tier classification of the touched pages; counts are
        # small enough that a float64 BLAS matvec is exact (< 2**53), and
        # every touched page is allocated, so pacc_s is the complement
        tiers_all = tier_b[:, pages]  # [n_sizes, n_touched]
        counts_f = counts_mem.astype(np.float64)
        fast_f = (tiers_all == fast_code).astype(np.float64)
        if profilers is None:
            pacc_f_all = (fast_f @ counts_f).astype(np.int64)
        else:
            # what simulate()'s profiler records per interval, batched in
            # one GEMM: reported touches saturate at hot_thr, warm =
            # below-threshold fast-tier observations
            rep = np.minimum(ia.touches, hot_thr)
            rep_f = rep.astype(np.float64)
            warm = (rep < hot_thr).astype(np.float64)
            sums = (
                fast_f
                @ np.stack([counts_f, rep_f, warm, rep_f * warm], axis=1)
            ).astype(np.int64)
            pacc_f_all = sums[:, 0]
            ptouch_f_all = sums[:, 1]
            ptouch_s_all = int(rep.sum()) - ptouch_f_all
            warm_pages_all = sums[:, 2]
            warm_touch_all = sums[:, 3]
        pacc_s_all = int(counts_mem.sum()) - pacc_f_all
        # --- promotion candidates: touch counts are size-independent, so
        # the hottest-first stable order is computed once; each size keeps
        # its slow-tier subset (subsets preserve the stable order)
        acc_now = interval_touch[pages]
        hot_mask = acc_now >= policy.hot_thr
        hot_sorted = pages[hot_mask]
        acc_hot = acc_now[hot_mask]
        if acc_hot.size:
            vmax = int(acc_hot.max())
            if vmax - policy.hot_thr <= 32:
                # touch counts span a handful of values: a stable counting
                # sort (hottest first) beats argsort on tens of thousands
                # of candidates, with the identical tie order
                order = np.concatenate(
                    [
                        np.flatnonzero(acc_hot == v)
                        for v in range(vmax, policy.hot_thr - 1, -1)
                    ]
                )
            else:
                order = np.argsort(-acc_hot, kind="stable")
            hot_sorted = hot_sorted[order]
        hot_unique = bool(
            hot_sorted.size
            and int(
                np.bincount(hot_sorted, minlength=num_pages).max()
            ) <= 1
        )
        # one batched gather for every size's promotion-candidate filter
        cand_slow_all = (
            tier_b[:, hot_sorted] == slow_code
            if hot_sorted.size
            else None
        )
        # --- per-size policy + cost (identical code path to simulate())
        for s, pool in enumerate(pools):
            before_direct = pool.stats.pgdemote_direct
            if profilers is not None:
                profilers[s].record_accesses(
                    int(ptouch_f_all[s]),
                    int(ptouch_s_all[s]),
                    ia.ops,
                    cachelines=int(pacc_f_all[s]) + int(pacc_s_all[s]),
                    warm_pages=int(warm_pages_all[s]),
                    warm_touches=int(warm_touch_all[s]),
                )
            cand = (
                hot_sorted[cand_slow_all[s]]
                if cand_slow_all is not None
                else hot_sorted
            )
            outcome = policy.step_hot_sorted(
                pool, cand, assume_unique=hot_unique
            )
            if profilers is not None:
                profilers[s].record_policy(outcome)
                configs_out[s].append(profilers[s].finish(pool))
            cost = interval_time(
                hw,
                pacc_f=int(pacc_f_all[s]),
                pacc_s=int(pacc_s_all[s]),
                ops=ia.ops,
                pm_pr=outcome.pm_pr,
                pm_de=outcome.pm_de,
                pm_fail=outcome.pm_fail,
                direct_reclaimed=pool.stats.pgdemote_direct - before_direct,
                mlp_eff=mlp_eff,
                num_threads=trace.num_threads,
                rand_frac=ia.rand_frac,
            )
            times[s, i] = cost.total
        # --- one shared heat fold for all sizes (mirrors
        # TieredPagePool.end_interval's dense/indexed hybrid)
        if pages.size >= num_pages // 8:
            heat.fold_dense(interval_touch)
            interval_touch[:] = 0
        elif pages.size:
            heat.fold(pages, interval_touch[pages])
            interval_touch[pages] = 0
        else:
            heat.fold(np.empty(0, np.int64), np.empty(0, np.int64))
    return SweepResult(
        name=trace.name,
        fm_fracs=fm_fracs,
        interval_times=times,
        stats=[pool.stats.snapshot() for pool in pools],
        configs=configs_out,
    )


def sweep_times(
    trace: Trace,
    fm_fracs,
    hot_thr: int = 4,
    hw: HardwareProfile = OPTANE_LIKE,
) -> np.ndarray:
    """Total execution time per fm fraction (the database-build backend)."""
    return sweep_fm_fracs(trace, fm_fracs, hot_thr=hot_thr, hw=hw).total_times
