"""Analyzer core: the rule registry, source model, and the run driver.

The registry mirrors :data:`repro.tiering.policy.POLICIES`: a rule is a
class with a unique ``code``, registered with :func:`register_rule`, one
per module under :mod:`repro.analysis.rules`. Everything in this
package is stdlib only — the analyzer adds no dependency.
"""

from __future__ import annotations

import ast
import hashlib
import re
from dataclasses import dataclass, field
from pathlib import Path

# comment marker: "# tuna: ignore[TUNA001]" / "# tuna: ignore[TUNA001,TUNA007] why"
_SUPPRESS_RE = re.compile(r"#\s*tuna:\s*ignore\[([A-Za-z0-9_\s,]+)\]")

# directories never scanned (generated/cache/VCS trees)
_SKIP_DIRS = {"__pycache__", ".git", "_cache", ".pytest_cache", ".ruff_cache"}


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str  # "TUNA004"
    path: str  # root-relative posix path
    line: int  # 1-based first line of the offending node
    message: str
    snippet: str = ""  # stripped source of the first line (fingerprint input)
    end_line: int = 0  # last line of the node (suppression range); 0 = line
    # pin-backed findings (frozen digest, schema fingerprint) cannot be
    # grandfathered in the baseline findings list — --update-baseline
    # resolves them by refreshing the pin instead
    baselinable: bool = True

    @property
    def fingerprint(self) -> str:
        """Line-number-independent identity: rule + path + normalized
        source text. Identical lines in one file share a fingerprint (one
        baseline entry covers all of them); unrelated edits that move the
        line do not invalidate the entry."""
        norm = re.sub(r"\s+", " ", self.snippet).strip()
        return hashlib.sha1(
            f"{self.rule}:{self.path}:{norm}".encode()
        ).hexdigest()[:16]

    def location(self) -> str:
        return f"{self.path}:{self.line}"


class ModuleSource:
    """One parsed source file: text, lines, lazy AST, suppression map."""

    def __init__(self, root: Path, relpath: str, text: str):
        self.root = root
        self.relpath = relpath
        self.text = text
        self.lines = text.splitlines()
        self._tree: ast.Module | None = None
        self.parse_error: SyntaxError | None = None
        self._suppress: dict[int, set[str]] | None = None

    @property
    def tree(self) -> ast.Module | None:
        if self._tree is None and self.parse_error is None:
            try:
                self._tree = ast.parse(self.text, filename=self.relpath)
            except SyntaxError as e:
                self.parse_error = e
        return self._tree

    def line_at(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    # ------------------------------------------------------- suppressions
    @property
    def suppressions(self) -> dict[int, set[str]]:
        """1-based line -> set of rule codes suppressed on that line.

        A marker on a code line suppresses that line; a marker on a
        comment-only line suppresses the first following non-comment line
        (intervening comment-only lines may continue the justification).
        """
        if self._suppress is None:
            sup: dict[int, set[str]] = {}
            for i, raw in enumerate(self.lines, start=1):
                m = _SUPPRESS_RE.search(raw)
                if not m:
                    continue
                codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
                sup.setdefault(i, set()).update(codes)
                if raw.strip().startswith("#"):
                    j = i + 1
                    while j <= len(self.lines) and (
                        not self.lines[j - 1].strip()
                        or self.lines[j - 1].strip().startswith("#")
                    ):
                        j += 1
                    if j <= len(self.lines):
                        sup.setdefault(j, set()).update(codes)
            self._suppress = sup
        return self._suppress

    def is_suppressed(self, f: Finding) -> bool:
        last = max(f.end_line, f.line)
        return any(
            f.rule in self.suppressions.get(ln, ())
            for ln in range(f.line, last + 1)
        )


class Project:
    """The scanned tree: root, modules, and the loaded baseline (pins)."""

    def __init__(self, root: Path, modules: list[ModuleSource], baseline=None):
        self.root = Path(root)
        self.modules = modules
        self.baseline = baseline  # repro.analysis.baseline.Baseline | None
        self._by_path = {m.relpath: m for m in modules}

    def module(self, relpath: str) -> ModuleSource | None:
        return self._by_path.get(relpath)

    def read_bytes(self, relpath: str) -> bytes | None:
        """Raw bytes of a root-relative file (digest pinning), scanned or
        not; None when absent."""
        p = self.root / relpath
        try:
            return p.read_bytes()
        except OSError:
            return None


# --------------------------------------------------------------- registry

# code -> Rule subclass; populated by @register_rule (one rule per module
# under repro.analysis.rules, mirroring the POLICIES pattern)
RULES: dict[str, type] = {}

_CODE_RE = re.compile(r"^[A-Z]+[0-9]{3}$")


def register_rule(cls):
    """Class decorator: add ``cls`` to :data:`RULES` under its ``code``.
    Re-registering the same class is a no-op; a different class under a
    taken code is an error (no silent shadowing)."""
    code = getattr(cls, "code", None)
    if not isinstance(code, str) or not _CODE_RE.match(code):
        raise ValueError(
            f"rule class {cls.__name__} needs a code like 'TUNA001', "
            f"got {code!r}"
        )
    prev = RULES.get(code)
    if prev is not None and prev is not cls:
        raise ValueError(
            f"rule code {code!r} is already registered to "
            f"{prev.__name__}; refusing to shadow it with {cls.__name__}"
        )
    RULES[code] = cls
    return cls


class Rule:
    """Base class for one invariant contract.

    ``scope`` path fragments select the files the rule sees (posix
    relpath substring match, ``()`` = every scanned file); ``exempt``
    fragments carve out exceptions. ``project_level`` rules run once per
    analysis over the whole :class:`Project` (digest pinning, schema
    fingerprints) instead of per file.
    """

    code = ""
    name = ""
    description = ""
    scope: tuple[str, ...] = ()
    exempt: tuple[str, ...] = ()
    project_level = False

    def applies(self, relpath: str) -> bool:
        p = relpath.replace("\\", "/")
        if any(x in p for x in self.exempt):
            return False
        return not self.scope or any(s in p for s in self.scope)

    def check(self, mod: ModuleSource) -> list[Finding]:
        return []

    def check_project(self, project: Project) -> list[Finding]:
        return []

    def pin(self, project: Project) -> dict | None:
        """Data ``--update-baseline`` stores under the rule's code in the
        baseline ``pins`` section (digests, schema fingerprints); None
        for rules with no pinned state."""
        return None

    # ---------------------------------------------------------- helpers
    def finding(
        self, mod: ModuleSource, node: ast.AST, message: str, **kw
    ) -> Finding:
        line = getattr(node, "lineno", 1)
        return Finding(
            rule=self.code,
            path=mod.relpath,
            line=line,
            message=message,
            snippet=mod.line_at(line),
            end_line=getattr(node, "end_lineno", line) or line,
            **kw,
        )


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, None for anything dynamic."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ------------------------------------------------------------ file walking


def collect_files(root: Path, paths: list[str]) -> list[str]:
    """Resolve CLI path arguments into a sorted, deduplicated list of
    root-relative posix ``*.py`` paths."""
    out: set[str] = set()
    for p in paths:
        full = (root / p) if not Path(p).is_absolute() else Path(p)
        if full.is_file() and full.suffix == ".py":
            out.add(full.resolve().relative_to(root.resolve()).as_posix())
        elif full.is_dir():
            for f in sorted(full.rglob("*.py")):
                if any(part in _SKIP_DIRS for part in f.parts):
                    continue
                out.add(f.resolve().relative_to(root.resolve()).as_posix())
    return sorted(out)


def load_project(
    root: Path, relpaths: list[str], baseline=None
) -> Project:
    mods = []
    for rp in relpaths:
        try:
            text = (root / rp).read_text(encoding="utf-8")
        except (OSError, UnicodeDecodeError):
            continue
        mods.append(ModuleSource(root, rp, text))
    return Project(root, mods, baseline=baseline)


# ------------------------------------------------------------- run driver


@dataclass
class AnalysisResult:
    """Outcome of one analyzer run, pre-split for reporting."""

    findings: list[Finding] = field(default_factory=list)  # active (gate)
    suppressed: list[Finding] = field(default_factory=list)
    baselined: list[Finding] = field(default_factory=list)
    stale_baseline: list[dict] = field(default_factory=list)
    files_scanned: int = 0
    rules_run: list[str] = field(default_factory=list)


def instantiate_rules(select: list[str] | None = None) -> list[Rule]:
    """Construct the selected rules in code order; unknown codes raise
    ValueError listing what is registered (mirrors resolve_policy)."""
    import repro.analysis.rules  # noqa: F401  (registers on import)

    if select:
        unknown = sorted(set(select) - set(RULES))
        if unknown:
            raise ValueError(
                f"unknown rule code(s) {unknown}; registered: "
                f"{sorted(RULES)}"
            )
        codes = sorted(set(select))
    else:
        codes = sorted(RULES)
    return [RULES[c]() for c in codes]


def run_analysis(
    root: Path,
    relpaths: list[str],
    baseline=None,
    select: list[str] | None = None,
) -> tuple[AnalysisResult, Project]:
    """Run the selected rules over ``relpaths`` and classify every raw
    finding as active, suppressed (``# tuna: ignore``), or baselined."""
    rules = instantiate_rules(select)
    project = load_project(root, relpaths, baseline=baseline)
    res = AnalysisResult(
        files_scanned=len(project.modules),
        rules_run=[r.code for r in rules],
    )

    raw: list[Finding] = []
    for mod in project.modules:
        applicable = [
            r for r in rules if not r.project_level and r.applies(mod.relpath)
        ]
        if applicable and mod.tree is None and mod.parse_error is not None:
            e = mod.parse_error
            raw.append(
                Finding(
                    rule="PARSE",
                    path=mod.relpath,
                    line=e.lineno or 1,
                    message=f"syntax error: {e.msg}",
                    snippet=mod.line_at(e.lineno or 1),
                    baselinable=False,
                )
            )
            continue
        for r in applicable:
            raw.extend(r.check(mod))
    for r in rules:
        if r.project_level:
            raw.extend(r.check_project(project))

    matched_keys: set[tuple[str, str, str]] = set()
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.rule)):
        mod = project.module(f.path)
        if mod is not None and mod.is_suppressed(f):
            res.suppressed.append(f)
            continue
        if baseline is not None and f.baselinable and baseline.covers(f):
            res.baselined.append(f)
            matched_keys.add((f.rule, f.path, f.fingerprint))
            continue
        res.findings.append(f)

    if baseline is not None:
        scanned = set(relpaths)
        ran = set(res.rules_run)
        for entry in baseline.findings:
            key = (entry["rule"], entry["path"], entry["fingerprint"])
            if (
                entry["path"] in scanned
                and entry["rule"] in ran
                and key not in matched_keys
            ):
                res.stale_baseline.append(entry)
    return res, project
