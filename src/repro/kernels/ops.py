"""Public kernel API with platform dispatch.

On TPU (or with ``REPRO_PALLAS=interpret`` for CPU validation) the Pallas
kernels are used; otherwise the jnp references. All model code calls
through this module, so swapping the backend never touches model code.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

_MODE = os.environ.get("REPRO_PALLAS", "auto")  # auto | interpret | off


def _use_pallas() -> bool:
    if _MODE == "off":
        return False
    if _MODE == "interpret":
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return _MODE == "interpret" or jax.default_backend() != "tpu"


def _shard_map(f, *, mesh, in_specs, out_specs):
    """Version-compat ``shard_map``: newer jax exports ``jax.shard_map``
    (replication checking via ``check_vma``); the pinned 0.4.x line only
    has ``jax.experimental.shard_map.shard_map`` (same knob named
    ``check_rep``). Resolve whichever this jax provides — replication
    checking stays off either way (the LSE merge's psum outputs are
    per-shard-identical by construction, which the checker cannot see).
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=False,
            )
        except TypeError:
            # intermediate releases export jax.shard_map with the old
            # check_rep spelling
            return sm(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=False,
            )
    from jax.experimental.shard_map import shard_map as sm

    return sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )


# --------------------------------------------------------------- attention
def attention(q, k, v, causal: bool = True):
    """Training/prefill attention; flash kernel on TPU, reference on CPU."""
    if _use_pallas():
        try:
            from repro.kernels import flash_attention as fa

            return fa.flash_attention(
                q, k, v, causal=causal, interpret=_interpret()
            )
        except Exception:
            if _MODE == "interpret":
                raise
    return _ref.attention(q, k, v, causal=causal)


def decode_attention(q, k_cache, v_cache, valid_len):
    return _ref.decode_attention(q, k_cache, v_cache, valid_len)


def cp_decode_attention(q, k_cache, v_cache, valid_len, mesh,
                        k_scale=None, v_scale=None,
                        batch_axis="data", seq_axis="model"):
    """Context-parallel decode attention (flash-decoding LSE merge).

    The KV cache is sequence-sharded over ``seq_axis``; each shard attends
    over its local chunk producing (m, l, o) partials, merged with the
    log-sum-exp rescale + psum across the axis. GSPMD cannot partition the
    softmax over a sharded contraction (it all-gathers K/V — 172 GB/step
    on the 72B decode cell); this shard_map formulation moves only the
    (B, H, hd) partials: ~3 MB/step (§Perf iteration 3).

    q (B,1,H,hd); k/v (B,S,KV,hd) [+ optional int8 scales (B,S,KV,1) —
    dequantization happens *inside* the shard so quantized bytes never
    cross links].
    """
    import math as _math

    from jax.sharding import PartitionSpec as P

    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    seq_n = sizes.get(seq_axis, 1)
    S_loc = S // seq_n
    if B % sizes.get(batch_axis, 1) != 0:
        batch_axis = None  # B=1 cells: replicate the batch dim

    quant = k_scale is not None

    def local(qb, kb, vb, ks, vs, vlen):
        i = jax.lax.axis_index(seq_axis)
        if quant:
            kb = kb.astype(jnp.bfloat16) * ks.astype(jnp.bfloat16)
            vb = vb.astype(jnp.bfloat16) * vs.astype(jnp.bfloat16)
        kx = jnp.repeat(kb, rep, axis=2) if rep > 1 else kb
        vx = jnp.repeat(vb, rep, axis=2) if rep > 1 else vb
        s = jnp.einsum(
            "bshd,bthd->bhst", qb.astype(jnp.float32), kx.astype(jnp.float32)
        ) / _math.sqrt(hd)
        tpos = i * S_loc + jnp.arange(S_loc)[None, None, None, :]
        s = jnp.where(tpos < vlen, s, -jnp.inf)
        m = jnp.max(s, axis=-1, keepdims=True)  # (b,h,1,1)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe), 0.0)
        l_loc = jnp.sum(p, axis=-1, keepdims=True)  # (b,h,1,1)
        o = jnp.einsum("bhst,bthd->bshd", p, vx.astype(jnp.float32))
        # ---- merge across the sequence shards (log-sum-exp rescale)
        m_g = jax.lax.pmax(m, seq_axis)
        m_g_safe = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_g_safe), 0.0)
        l_g = jax.lax.psum(l_loc * corr, seq_axis)  # (b,h,1,1)
        corr_o = jnp.moveaxis(corr, 1, 2)  # (b,1,h,1)
        o_g = jax.lax.psum(o * corr_o, seq_axis)  # (b,1,h,d)
        l_o = jnp.maximum(jnp.moveaxis(l_g, 1, 2), 1e-30)  # (b,1,h,1)
        return (o_g / l_o).astype(qb.dtype)

    qspec = P(batch_axis, None, None, None)
    kvspec = P(batch_axis, seq_axis, None, None)
    if quant:
        fn = _shard_map(
            local,
            mesh=mesh,
            in_specs=(qspec, kvspec, kvspec, kvspec, kvspec, P()),
            out_specs=qspec,
        )
        return fn(q, k_cache, v_cache, k_scale, v_scale, valid_len)
    fn = _shard_map(
        lambda qb, kb, vb, vlen: local(qb, kb, vb, None, None, vlen),
        mesh=mesh,
        in_specs=(qspec, kvspec, kvspec, P()),
        out_specs=qspec,
    )
    return fn(q, k_cache, v_cache, valid_len)


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths):
    if _use_pallas():
        try:
            from repro.kernels import paged_attention as pa

            return pa.paged_decode_attention(
                q, k_pages, v_pages, page_table, lengths, interpret=_interpret()
            )
        except Exception:
            if _MODE == "interpret":
                raise
    return _ref.paged_decode_attention(q, k_pages, v_pages, page_table, lengths)


def wkv6(r, k, v, w, u):
    if _use_pallas():
        try:
            from repro.kernels import rwkv6_chunk as rk

            return rk.wkv6_chunked(r, k, v, w, u, interpret=_interpret())
        except Exception:
            if _MODE == "interpret":
                raise
    return _ref.wkv6(r, k, v, w, u)


def migrate_pages(dst_pool, src_pool, dst_idx, src_idx):
    if _use_pallas():
        try:
            from repro.kernels import page_migrate as pm

            return pm.migrate_pages(
                dst_pool, src_pool, dst_idx, src_idx, interpret=_interpret()
            )
        except Exception:
            if _MODE == "interpret":
                raise
    return _ref.migrate_pages(dst_pool, src_pool, dst_idx, src_idx)


def strided_probe(fast_arr, slow_arr, fast_idx, slow_idx, ai_iters: int):
    if _use_pallas():
        try:
            from repro.kernels import strided_probe as sp

            return sp.strided_probe(
                fast_arr, slow_arr, fast_idx, slow_idx, ai_iters,
                interpret=_interpret(),
            )
        except Exception:
            if _MODE == "interpret":
                raise
    return _ref.strided_probe(fast_arr, slow_arr, fast_idx, slow_idx, ai_iters)


# ------------------------------------------------------------ bench hooks
def _bench_attention():
    q = jnp.ones((2, 128, 8, 64), jnp.bfloat16)
    k = jnp.ones((2, 128, 4, 64), jnp.bfloat16)
    return jax.jit(attention)(q, k, k).block_until_ready()


def _bench_wkv6():
    B, S, H, hd = 2, 64, 4, 32
    r = jnp.ones((B, S, H, hd), jnp.float32) * 0.1
    u = jnp.zeros((H, hd))
    o, _ = jax.jit(wkv6)(r, r, r, r * 0.5, u)
    return o.block_until_ready()


BENCH_CASES = {
    "attention_2x128": _bench_attention,
    "wkv6_2x64": _bench_wkv6,
}
