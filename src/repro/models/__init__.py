"""Composable model definitions (pure JAX, no external NN library).

Every architecture in the assigned pool is expressed as a
:class:`repro.models.config.ModelConfig` over one block stack
(:mod:`repro.models.transformer`): dense GQA (with qk-norm / QKV-bias /
2d-RoPE variants), MLA, fine-grained MoE with shared experts, Mamba, RWKV6,
and encoder-decoder — each block type implemented in
:mod:`repro.models.layers` as an (init, apply) pair over plain parameter
pytrees, with a parallel PartitionSpec tree for GSPMD sharding
(:mod:`repro.models.sharding`).
"""

from repro.models.config import ModelConfig
from repro.models.transformer import (
    active_param_count,
    active_param_count_shapes,
    decode_step,
    encode,
    forward,
    init_decode_state,
    init_model,
    model_flops,
    param_count,
    prefill,
)

__all__ = [
    "ModelConfig",
    "active_param_count",
    "active_param_count_shapes",
    "decode_step",
    "encode",
    "forward",
    "init_decode_state",
    "init_model",
    "model_flops",
    "param_count",
    "prefill",
]
