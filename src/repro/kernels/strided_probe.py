"""The Tuna micro-benchmark as a Pallas TPU kernel.

On real tiered hardware this is the workload that populates the
performance database: strided page reads from two pools (the fast-tier and
slow-tier arrays of Section 3.2) with a controlled number of arithmetic
ops per loaded element (the AI knob). The page-id vectors are scalar
prefetch operands; each grid step streams one page through VMEM and runs
``ai_iters`` fused multiply-adds per element, accumulating a checksum so
nothing is dead-code eliminated.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _probe_kernel(fast_idx_ref, slow_idx_ref, fast_ref, slow_ref, out_ref,
                  acc_scr, *, n_fast: int, ai_iters: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # first n_fast grid steps stream fast pages, the rest slow pages
    x = jnp.where(i < n_fast, fast_ref[...], slow_ref[...]).astype(jnp.float32)

    def body(_, acc):
        # tuna: ignore[TUNA004] deliberately FMA-shaped: the probe wants
        # peak-rate arithmetic per element, not a numeric contract
        return acc * 1.000001 + x

    acc = jax.lax.fori_loop(0, ai_iters, body, jnp.zeros_like(x))
    acc_scr[...] += jnp.sum(acc, axis=0, keepdims=True)

    @pl.when(i == pl.num_programs(0) - 1)
    def _emit():
        out_ref[...] = acc_scr[...]


@functools.partial(jax.jit, static_argnames=("ai_iters", "interpret"))
def strided_probe(fast_pool, slow_pool, fast_idx, slow_idx, ai_iters: int,
                  interpret: bool = False):
    """fast_pool/slow_pool (P, page_elems) f32; fast_idx (nf,), slow_idx
    (ns,) int32 page ids. Returns the checksum (1, page_elems)."""
    nf, ns = fast_idx.shape[0], slow_idx.shape[0]
    page_elems = fast_pool.shape[1]
    kernel = functools.partial(_probe_kernel, n_fast=nf, ai_iters=ai_iters)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nf + ns,),
        in_specs=[
            pl.BlockSpec(
                (1, page_elems),
                lambda i, fi, si: (fi[jnp.minimum(i, fi.shape[0] - 1)], 0),
            ),
            pl.BlockSpec(
                (1, page_elems),
                lambda i, fi, si: (
                    si[jnp.clip(i - fi.shape[0], 0, si.shape[0] - 1)],
                    0,
                ),
            ),
        ],
        out_specs=pl.BlockSpec((1, page_elems), lambda i, fi, si: (0, 0)),
        scratch_shapes=[pltpu.VMEM((1, page_elems), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, page_elems), jnp.float32),
        interpret=interpret,
    )(fast_idx.astype(jnp.int32), slow_idx.astype(jnp.int32),
      fast_pool, slow_pool)
