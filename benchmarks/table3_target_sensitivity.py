"""Table 3 + Section 6.3: sensitivity studies on SSSP.

(a) Performance-loss target τ ∈ {5%, 10%, 15%}: paper reports fast-memory
    savings 9% / 18% / 27% with losses 4.6% / 9.6% / 15.1% (the 15% case
    slightly violates because model error grows with shrink).
(b) Tuning frequency {0.5 s, 1 s, 2.5 s, 5 s}: smaller intervals save more
    memory but lose more performance (paper: 0.5 s → up to 25% saving but
    17% loss; 5 s → ~2% saving, ~3% loss).

The whole target/interval matrix — seven tuner configurations plus the
TPP-only baseline — is one declarative experiment over the SSSP trace
(eight policy specs of a single scenario), which the
:func:`repro.sim.api.run` planner executes as **one** batched tuned sweep
instead of the old fifteen per-configuration ``simulate()`` passes (each
old run also re-ran its own baseline).
"""

from __future__ import annotations

import time

from benchmarks.common import build_bench_db, get_trace
from benchmarks.fig3_7_tuning import TUNE_EVERY, run_tuned_slices, summarize

# (report label, target_loss, tune_every)
SPECS = (
    ("table3/sssp_tau5", 0.05, TUNE_EVERY),
    ("table3/sssp_tau10", 0.10, TUNE_EVERY),
    ("table3/sssp_tau15", 0.15, TUNE_EVERY),
    ("interval/sssp_0.5s", 0.05, 1),
    ("interval/sssp_1s", 0.05, 2),
    ("interval/sssp_2.5s", 0.05, 3),
    ("interval/sssp_5s", 0.05, 6),
)


def run(report) -> None:
    db = build_bench_db()
    tr = get_trace("sssp")
    t0 = time.time()
    base, results = run_tuned_slices(
        tr, db, [(tau, te) for _, tau, te in SPECS]
    )
    # one sweep produced every row: report each row's amortized share so
    # summing the us column still totals one sweep, as it totalled the
    # per-run times before the batching
    per_row_us = (time.time() - t0) * 1e6 / len(SPECS)
    for (label, _, _), res in zip(SPECS, results):
        saving, max_saving, overall_loss = summarize(base, res, tr)
        report(
            label,
            per_row_us,
            f"saving={saving*100:.1f}%;max_saving={max_saving*100:.1f}%"
            f";loss={overall_loss*100:.2f}%",
        )
