"""Tuna's online component: the runtime tuner (paper Sections 3.3, 4, 5).

Every tuning interval (default 2.5 s) the tuner:

1. collects the interval's telemetry (``ConfigVector``) from the profiler;
2. queries the performance database for the nearest execution record;
3. from that record, picks the **minimum fast-memory size whose predicted
   relative loss ≤ τ** (the user's performance-loss target); if no size
   qualifies, the current size is kept (paper Section 3.3);
4. actuates via the watermark controller, so reclamation happens in the
   background.

The offline component — sweeping configuration vectors through the
micro-benchmark across fast-memory sizes to populate the database — is
:func:`build_database`; the execution backend (simulator here, real tiered
hardware in production) is injected as a callable.
"""

from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass, field
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.microbench import generate_microbench
from repro.core.perfdb import PerfDB, PerfDBUnavailable, PerfRecord
from repro.core.telemetry import ConfigVector
from repro.core.trace import Trace
from repro.core.watermark import WatermarkController


@dataclass
class TunerConfig:
    target_loss: float = 0.05  # τ, the user's performance-loss target
    tuning_interval_s: float = 2.5  # paper default
    k_neighbors: int = 3  # records averaged for robustness
    min_fm_frac: float = 0.05  # never shrink below this fraction of peak
    # Closed-loop feedback guard (beyond-paper extension, DESIGN.md §8):
    # the paper's tuner is open loop against the database; when the
    # database's even-spread micro-benchmark underestimates deep-shrink
    # loss, this guard compares *measured* time-per-access against the
    # full-fm reference and grows the fast tier back once the target is
    # exceeded. Disable for the paper-faithful configuration.
    feedback: bool = True
    feedback_margin: float = 1.0  # grow when loss > margin × τ
    cooldown_windows: int = 3  # block DB shrink after a feedback grow
    # Degradation modes (robustness extension): consecutive PerfDB
    # failures tolerated (each retried at the next window, with
    # exponential backoff between attempts) before the tuner stops
    # querying every window and freezes the watermarks at the current
    # size until a query succeeds again.
    db_retry_limit: int = 3
    # Hysteresis clamp: a shrink request deeper than one controller step
    # below the current size must be confirmed by the *next* tuning
    # window before it proceeds, so a single noisy telemetry interval
    # cannot trigger a multi-step shrink. Off by default (bit-exact with
    # the pre-fault-model tuner); the fault injector arms it when
    # telemetry noise is configured.
    shrink_confirm: bool = False


@dataclass
class TunerDecision:
    t: float
    config: ConfigVector
    fm_frac: float | None  # chosen fraction (None = keep current)
    fm_pages: int  # actuated size
    predicted_loss: float | None
    # why this decision ran degraded, if it did: "telemetry_dropout",
    # "db_outage", "db_backoff", "db_outage_frozen", "shrink_unconfirmed"
    degraded: str | None = None


@dataclass
class TunaTuner:
    db: PerfDB
    controller: WatermarkController
    cfg: TunerConfig = field(default_factory=TunerConfig)
    peak_rss_pages: int | None = None
    decisions: list = field(default_factory=list)
    # a FaultInjector armed by repro.sim.faults (kept untyped: no cycle);
    # None in production unless a run wires one in
    fault_injector: object | None = None
    _ref_tpa: float | None = None  # time/access EMA at (near-)full fm
    _cooldown: int = 0
    _floor_frac: float = 0.0  # learned lower bound from feedback violations
    _step_idx: int = -1  # tuning-step counter (keys db-outage windows)
    _db_fail_streak: int = 0  # consecutive PerfDB failures
    _db_backoff: int = 0  # windows left before the next query retry
    _shrink_armed: bool = False  # deep-shrink request awaiting confirmation

    def bind_pool(self, pool, peak_rss_pages: int | None = None) -> "TunaTuner":
        """Attach the pool this tuner actuates (via its controller).

        The single entry point both execution paths use to wire a tuner
        into a run: :func:`repro.sim.engine.simulate` binds its one pool,
        and :func:`repro.sim.sweep.sweep_tuned` binds each size-slice's
        pool to that slice's tuner. ``peak_rss_pages`` anchors the tuner's
        fm-fraction arithmetic (defaults to the pool's hardware capacity).
        Returns self.
        """
        self.controller.bind(pool)
        self.peak_rss_pages = (
            int(peak_rss_pages) if peak_rss_pages is not None
            else int(pool.hw_capacity)
        )
        return self

    def _hold(self, cv, t, degraded=None, predicted_loss=None) -> TunerDecision:
        """A keep-current-size decision (optionally marked degraded)."""
        d = TunerDecision(
            t=t, config=cv, fm_frac=None,
            fm_pages=self.controller.pool.effective_fm_size,
            predicted_loss=predicted_loss, degraded=degraded,
        )
        self.decisions.append(d)
        return d

    def step(
        self,
        cv: ConfigVector,
        t: float = 0.0,
        measured_tpa: float | None = None,
        telemetry_ok: bool = True,
    ) -> TunerDecision:
        """One tuning step: telemetry in, watermark actuation out.

        ``measured_tpa`` — measured time per memory access this tuning
        window; feeds the closed-loop guard when cfg.feedback is on.
        ``telemetry_ok=False`` marks this window's telemetry as missing
        or stale (profiler dropout): the tuner holds its last decision —
        neither the feedback guard nor the database may act on counters
        that never arrived.
        """
        self._step_idx += 1
        peak = self.peak_rss_pages or self.controller.pool.hw_capacity
        cur_frac = self.controller.pool.effective_fm_size / peak
        if not telemetry_ok or cv is None:
            return self._hold(cv, t, degraded="telemetry_dropout")
        if self.cfg.feedback and measured_tpa is not None and measured_tpa > 0:
            if cur_frac >= 0.97:
                # conservative reference: the best (minimum) time-per-access
                # observed at (near-)full size — an EMA gets polluted by
                # post-thrash recovery intervals and then under-reports loss
                self._ref_tpa = (
                    measured_tpa
                    if self._ref_tpa is None
                    else min(self._ref_tpa, measured_tpa)
                )
            elif self._ref_tpa is not None:
                loss_now = measured_tpa / self._ref_tpa - 1.0
                if loss_now > self.cfg.feedback_margin * self.cfg.target_loss:
                    # measured violation: grow one controller step, learn a
                    # floor, and hold off database shrinks for a cooldown
                    # grow hard (two controller steps) — thrash is expensive
                    step_pages = max(
                        1, int(2 * self.controller.max_step_frac * peak)
                    )
                    new = self.controller.set_size(
                        self.controller.pool.effective_fm_size + step_pages, t=t
                    )
                    new = self.controller.set_size(
                        min(peak, new + step_pages), t=t
                    )
                    self._cooldown = self.cfg.cooldown_windows
                    self._floor_frac = max(self._floor_frac, new / peak)
                    d = TunerDecision(
                        t=t, config=cv, fm_frac=new / peak, fm_pages=new,
                        predicted_loss=loss_now,
                    )
                    self.decisions.append(d)
                    return d
        if self._cooldown > 0:
            self._cooldown -= 1
            return self._hold(cv, t)
        # --- PerfDB degradation: retry with backoff, then freeze.
        # Failed queries hold the current size (frozen watermarks); each
        # consecutive failure doubles the number of tuning windows skipped
        # before the next retry, and past cfg.db_retry_limit the decision
        # is surfaced as "db_outage_frozen" — the loop never raises.
        if self._db_backoff > 0:
            self._db_backoff -= 1
            return self._hold(cv, t, degraded="db_backoff")
        fi = self.fault_injector
        outage = fi is not None and fi.db_outage(
            self.controller.pool, self._step_idx
        )
        records = None
        if not outage:
            try:
                records = self.db.query(cv, k=self.cfg.k_neighbors)
            except PerfDBUnavailable:
                outage = True
        if outage:
            self._db_fail_streak += 1
            self._db_backoff = min(2 ** (self._db_fail_streak - 1), 8)
            frozen = self._db_fail_streak > self.cfg.db_retry_limit
            return self._hold(
                cv, t, degraded="db_outage_frozen" if frozen else "db_outage"
            )
        self._db_fail_streak = 0
        frac, loss = self._choose(records)
        if frac is None:
            decision = TunerDecision(
                t=t,
                config=cv,
                fm_frac=None,
                fm_pages=self.controller.pool.effective_fm_size,
                predicted_loss=None,
            )
        else:
            frac = max(frac, self.cfg.min_fm_frac, self._floor_frac)
            degraded = None
            if self.cfg.shrink_confirm:
                # hysteresis clamp: a multi-step shrink request must
                # repeat on the next window before it proceeds
                ms = self.controller.max_step_frac
                if frac < cur_frac - ms - 1e-12:
                    if not self._shrink_armed:
                        self._shrink_armed = True
                        frac = max(frac, cur_frac - ms)
                        degraded = "shrink_unconfirmed"
                else:
                    self._shrink_armed = False
            new_fm = int(round(frac * peak))
            actual = self.controller.set_size(new_fm, t=t)
            decision = TunerDecision(
                t=t, config=cv, fm_frac=frac, fm_pages=actual,
                predicted_loss=loss, degraded=degraded,
            )
        self.decisions.append(decision)
        return decision

    def _choose(self, records: Sequence[PerfRecord]):
        """Min fm fraction whose k-NN-averaged predicted loss ≤ τ."""
        if not records:
            return None, None
        # average loss curves over the k nearest records on a common grid;
        # drop records whose loss curve is non-finite (degraded microbench
        # runs: NaN/inf times, or a zero baseline) — one would poison the
        # whole average
        grid = records[0].fm_fracs
        losses = []
        for r in records:
            pl = r.predicted_loss()
            if not np.all(np.isfinite(pl)):
                warnings.warn(
                    "TunaTuner._choose: skipping record with non-finite "
                    f"loss curve (rss_pages={r.config.rss_pages:g})",
                    RuntimeWarning,
                    stacklevel=2,
                )
                continue
            if r.fm_fracs.shape == grid.shape and np.allclose(r.fm_fracs, grid):
                losses.append(pl)
            else:
                losses.append(
                    np.interp(grid[::-1], r.fm_fracs[::-1], pl[::-1])[::-1]
                )
        if not losses:
            return None, None
        loss = np.mean(losses, axis=0)
        ok = loss <= self.cfg.target_loss + 1e-12
        if not np.any(ok):
            return None, None
        i = int(np.argmin(np.where(ok, grid, np.inf)))
        return float(grid[i]), float(loss[i])


def scale_config(cv: ConfigVector, max_rss_pages: int) -> ConfigVector:
    """Scale a configuration down to a bounded RSS for micro-benchmarking.

    The database stores *relative* loss curves (Section 3.3), which are
    invariant to a uniform scaling of (pacc, pm, RSS): the micro-benchmark
    for a 3M-page workload and its 20K-page scaling predict the same
    loss-vs-fm_frac curve, at 150x the build cost difference. AI, hot_thr,
    and num_threads are intensive quantities and stay fixed.
    """
    lam = min(1.0, max_rss_pages / max(cv.rss_pages, 1.0))
    if lam >= 1.0:
        return cv
    v = cv.as_array()
    v[0:4] *= lam  # pacc_f, pacc_s, pm_de, pm_pr
    v[5] *= lam  # rss
    return ConfigVector.from_array(v)


def _microbench_trace(
    cv: ConfigVector, n_intervals: int, max_rss_pages: int
) -> Trace:
    """Scenario trace factory for one database record's micro-benchmark.

    Module-level so :func:`repro.sim.api.run`'s process fan-out can pickle
    ``functools.partial(_microbench_trace, cv, ...)`` — the trace is then
    generated inside the worker instead of being shipped to it.
    """
    return generate_microbench(
        scale_config(cv, max_rss_pages), n_intervals=n_intervals
    )


def build_database(
    configs: Iterable[ConfigVector],
    run_microbench: Callable[[Trace, float], float] | None = None,
    fm_fracs: Sequence[float] | None = None,
    n_intervals: int = 20,
    max_rss_pages: int = 20_000,
    workers: int | None = None,
) -> PerfDB:
    """Offline: populate the performance database.

    By default (``run_microbench=None``) the whole build is **one
    declarative experiment** executed through :func:`repro.sim.api.run`:
    one :class:`~repro.sim.api.Scenario` per configuration (lazy
    micro-benchmark trace factory, ``fast_only_at_full`` for the
    NP_slow = 0 baseline variant at full size — paper Section 3.2/3.3)
    against the shared fm-size vector. The planner produces each record's
    curve in one batched sweep pass per scenario and fans scenarios out
    across processes (``workers``; ``None`` = serial below 12 configs,
    else one worker per core). The result is equivalent to running
    :func:`repro.sim.engine.run_trace` once per size — the engine
    equivalence tests pin this — at a fraction of the cost.

    A ``run_microbench(trace, fm_frac)`` callable can still be injected as
    the execution backend (it must run the micro-benchmark trace with the
    fast tier sized at ``fm_frac`` of the trace's RSS and return the
    execution time); on real tiered hardware that is the ``strided_probe``
    kernel under the production page-management system. Custom backends run
    serially, one (config, size) pair at a time.
    """
    if fm_fracs is None:
        fm_fracs = np.round(np.arange(1.0, 0.099, -0.02), 3)
    fm_fracs = np.asarray(fm_fracs, dtype=np.float64)
    configs = list(configs)
    db = PerfDB()
    from repro.sim.engine import run_trace

    if run_microbench is not None and run_microbench is not run_trace:
        # legacy/injected backend: per-(config, size) calls, serial
        for cv in configs:
            # index on the raw vector; benchmark the scaled-down equivalent
            trace = generate_microbench(
                scale_config(cv, max_rss_pages), n_intervals=n_intervals
            )
            times = np.empty(fm_fracs.shape, dtype=np.float64)
            for i, f in enumerate(fm_fracs):
                if f >= 1.0 - 1e-9:
                    times[i] = run_microbench(trace.fast_only(), 1.0)
                else:
                    times[i] = run_microbench(trace, float(f))
            db.add(PerfRecord(config=cv, fm_fracs=fm_fracs, times=times))
        db.build()
        return db

    if not configs:
        db.build()
        return db

    from repro.sim.api import Experiment, PolicySpec, Scenario
    from repro.sim.api import run as run_experiment

    scenario_names = [f"config[{i}]" for i in range(len(configs))]
    rs = run_experiment(
        Experiment(
            name="build_database",
            scenarios=[
                Scenario(
                    trace=functools.partial(
                        _microbench_trace, cv, n_intervals, max_rss_pages
                    ),
                    name=name,
                    fast_only_at_full=True,
                )
                for name, cv in zip(scenario_names, configs)
            ],
            fm_fracs=fm_fracs,
            policies=[PolicySpec()],
        ),
        parallelism=workers,
    )
    for name, cv in zip(scenario_names, configs):
        times = rs.total_times(scenario=name)
        db.add(PerfRecord(config=cv, fm_fracs=fm_fracs, times=times))
    db.build()
    return db
