"""Beyond-paper table: tiered-KV serving under HBM budget pressure.

Sweeps the HBM page budget (fraction of total KV footprint) for the
continuous-batching server and reports round-time percentiles, migration
traffic, and promotion failures — the TPU deployment surface of the
paper's technique (DESIGN.md §4), plus the Tuna-tuned row where the
budget is chosen by the runtime instead of fixed.
"""

from __future__ import annotations

import time

import numpy as np


def _mk(hbm_pages, total=4096, seed=0):
    from repro.serving import ContinuousBatcher, TieredPagedKV, TieredServer
    from repro.serving.kv_cache import KVPageConfig

    kv = TieredPagedKV(
        KVPageConfig(n_groups=4, page_size=16, kv_heads=2, head_dim=32),
        total_pages=total,
        hbm_capacity=hbm_pages,
        seed=seed,
    )
    batcher = ContinuousBatcher(
        n_sessions=400, page_size=16, max_batch=16, resumes_per_round=3.0,
        seed=seed,
    )
    return kv, batcher, TieredServer(kv, batcher)


def run(report) -> None:
    rounds = 600
    base = None
    for frac in (1.0, 0.5, 0.25, 0.125):
        t0 = time.time()
        hbm = int(4096 * frac)
        kv, batcher, server = _mk(hbm)
        server.run(rounds, drift_every=200)
        s = server.summary()
        if base is None:
            base = s["mean_round_ms"]
        report(
            f"serving/hbm_{int(frac*1000)}",
            (time.time() - t0) * 1e6,
            f"mean_ms={s['mean_round_ms']:.3f};p99_ms={s['p99_round_ms']:.3f}"
            f";slowdown={s['mean_round_ms']/base:.2f}x"
            f";migr_in={s['migrated_in']};fails={s['promote_failures']}",
        )
    # Tuna-tuned budget (the paper's loop on the serving tier)
    t0 = time.time()
    from repro.core import TunaTuner, TunerConfig, WatermarkController
    from repro.core.perfdb import PerfDB, PerfRecord
    from repro.core.telemetry import ConfigVector

    kv, batcher, _ = _mk(1024)
    grid = np.array([1.0, 0.85, 0.7, 0.55, 0.4, 0.25])
    db = PerfDB()
    for pacc in (200, 800, 2400):
        for pm in (2, 16, 64):
            loss = (pm / 32.0) * (1.0 / grid - 1.0) * 0.08
            db.add(PerfRecord(
                config=ConfigVector(pacc_f=pacc, pacc_s=pm, pm_de=pm,
                                    pm_pr=pm, ai=1e6, rss_pages=4096,
                                    hot_thr=2, num_threads=1),
                fm_fracs=grid, times=1.0 + loss,
            ))
    db.build()
    tuner = TunaTuner(
        db, WatermarkController(kv.pool, max_step_frac=0.1),
        TunerConfig(target_loss=0.05), peak_rss_pages=1024,
    )
    from repro.serving import TieredServer

    server = TieredServer(kv, batcher, tuner=tuner, tune_every=16)
    server.run(rounds, drift_every=200)
    s = server.summary()
    report(
        "serving/tuna_tuned",
        (time.time() - t0) * 1e6,
        f"mean_ms={s['mean_round_ms']:.3f};p99_ms={s['p99_round_ms']:.3f}"
        f";hbm_saving={s['fm_saving_vs_cap']*100:.1f}%"
        f";migr_in={s['migrated_in']};fails={s['promote_failures']}",
    )
