"""Beyond-paper table: tiered-KV serving under HBM budget pressure.

Sweeps the HBM page budget (fraction of total KV footprint) for the
continuous-batching server and reports round-time percentiles, migration
traffic, and promotion failures — the TPU deployment surface of the
paper's technique (DESIGN.md §4), plus the Tuna-tuned row where the
budget is chosen by the runtime instead of fixed.

The whole table is one declarative :class:`~repro.sim.api.Experiment`
executed through a **custom runner** (``backend="custom"``): the serving
engine is not the interval simulator, so the scenario carries
:func:`_serving_runner`, which builds the KV store + batcher + server per
(budget, policy) cell — constructing the Tuna tuner inside the run from
its :class:`~repro.sim.api.TunerSpec`, exactly like the simulator
backends do.
"""

from __future__ import annotations

import time

import numpy as np

from repro.sim.api import Experiment, PolicySpec, Scenario, TunerSpec
from repro.sim.api import run as run_experiment

TOTAL_PAGES = 4096
ROUNDS = 600
DRIFT_EVERY = 200
BUDGET_FRACS = (1.0, 0.5, 0.25, 0.125)


def _mk(hbm_pages, total=TOTAL_PAGES, seed=0):
    from repro.serving import ContinuousBatcher, TieredPagedKV
    from repro.serving.kv_cache import KVPageConfig

    kv = TieredPagedKV(
        KVPageConfig(n_groups=4, page_size=16, kv_heads=2, head_dim=32),
        total_pages=total,
        hbm_capacity=hbm_pages,
        seed=seed,
    )
    batcher = ContinuousBatcher(
        n_sessions=400, page_size=16, max_batch=16, resumes_per_round=3.0,
        seed=seed,
    )
    return kv, batcher


def _serving_db():
    """Synthetic loss-curve database for the serving tier (the paper's
    offline component stand-in on this engine)."""
    from repro.core.perfdb import PerfDB, PerfRecord
    from repro.core.telemetry import ConfigVector

    grid = np.array([1.0, 0.85, 0.7, 0.55, 0.4, 0.25])
    db = PerfDB()
    for pacc in (200, 800, 2400):
        for pm in (2, 16, 64):
            loss = (pm / 32.0) * (1.0 / grid - 1.0) * 0.08
            db.add(PerfRecord(
                config=ConfigVector(pacc_f=pacc, pacc_s=pm, pm_de=pm,
                                    pm_pr=pm, ai=1e6, rss_pages=TOTAL_PAGES,
                                    hot_thr=2, num_threads=1),
                fm_fracs=grid, times=1.0 + loss,
            ))
    db.build()
    return db


def _serving_runner(scenario, fm_frac, spec, db) -> dict:
    """Custom execution backend: one server run per (budget, policy) cell.

    ``fm_frac`` scales the HBM budget against the total KV footprint; a
    tuned spec puts the Tuna loop on the serving tier (the tuner is built
    from the spec inside this run and bound to the KV pool)."""
    from repro.serving import TieredServer

    t0 = time.time()
    p = scenario.params
    total = int(p.get("total_pages", TOTAL_PAGES))
    hbm = int(round(total * fm_frac))
    kv, batcher = _mk(hbm, total=total, seed=scenario.seed)
    if spec.tuner is not None:
        tuner = spec.tuner.build(db).bind_pool(kv.pool)
        server = TieredServer(
            kv, batcher, tuner=tuner, tune_every=spec.tuner.tune_every
        )
    else:
        server = TieredServer(kv, batcher)
    server.run(
        int(p.get("rounds", ROUNDS)),
        drift_every=int(p.get("drift_every", DRIFT_EVERY)),
    )
    summary = server.summary()
    summary["wall_s"] = time.time() - t0  # per-cell timing for the report
    return summary


def run(report) -> None:
    rs = run_experiment(
        Experiment(
            name="serving_tiered",
            scenarios=[
                Scenario(
                    name="serving",
                    runner=_serving_runner,
                    params={
                        "total_pages": TOTAL_PAGES,
                        "rounds": ROUNDS,
                        "drift_every": DRIFT_EVERY,
                    },
                )
            ],
            fm_fracs=BUDGET_FRACS,
            policies=[
                PolicySpec(label="fixed"),
                # Tuna-tuned budget (the paper's loop on the serving tier),
                # starting from the 25% budget the fixed row also visits
                PolicySpec(
                    label="tuna",
                    fm_frac=0.25,
                    tuner=TunerSpec(
                        target_loss=0.05, tune_every=16, max_step_frac=0.1
                    ),
                ),
            ],
        ),
        db=_serving_db(),
    )
    base = None
    for frac in BUDGET_FRACS:
        s = rs.result(policy="fixed", fm_frac=frac)
        if base is None:
            base = s["mean_round_ms"]
        report(
            f"serving/hbm_{int(frac*1000)}",
            s["wall_s"] * 1e6,
            f"mean_ms={s['mean_round_ms']:.3f};p99_ms={s['p99_round_ms']:.3f}"
            f";slowdown={s['mean_round_ms']/base:.2f}x"
            f";migr_in={s['migrated_in']};fails={s['promote_failures']}",
        )
    s = rs.result(policy="tuna")
    report(
        "serving/tuna_tuned",
        s["wall_s"] * 1e6,
        f"mean_ms={s['mean_round_ms']:.3f};p99_ms={s['p99_round_ms']:.3f}"
        f";hbm_saving={s['fm_saving_vs_cap']*100:.1f}%"
        f";migr_in={s['migrated_in']};fails={s['promote_failures']}",
    )
