"""Paged decode attention as a Pallas TPU kernel.

The KV cache lives in page pools (the same pages the Tuna-managed tier
migrates); each sequence owns a list of page ids. The page table and the
per-sequence lengths are *scalar-prefetch* operands
(``pltpu.PrefetchScalarGridSpec``) so the k/v BlockSpec index maps can
dereference them — the canonical TPU pattern for vLLM-style serving.

Grid: (B, pages_per_seq), page axis innermost/sequential, carrying online
softmax state in VMEM scratch. GQA: the query's KV-head group attends to
its slice of the page.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    tbl_ref, len_ref,  # scalar prefetch
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, page_size: int, sm_scale: float,
):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (H, hd)
    k = k_ref[0].astype(jnp.float32)  # (page_size, KV, hd)
    v = v_ref[0].astype(jnp.float32)
    H, hd = q.shape
    psize, KV, _ = k.shape
    rep = H // KV
    qg = q.reshape(KV, rep, hd)
    # scores (KV, rep, page_size)
    s = jax.lax.dot_general(
        qg, jnp.moveaxis(k, 1, 0), (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ) * sm_scale
    # mask: token position within the sequence = j*page_size + i
    pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)  # tuna: ignore[TUNA004] int32
    valid = (pos < len_ref[b]) & (tbl_ref[b, j] >= 0)
    s = jnp.where(valid, s, NEG_INF)
    s = s.reshape(H, psize)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # (H, psize)
    # tuna: ignore[TUNA004] online-softmax rescale: model kernel with
    # float-tolerance tests, no bit-exact-vs-numpy contract; FMA welcome
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    pg = p.reshape(KV, rep, psize)
    pv = jax.lax.dot_general(
        pg, jnp.moveaxis(v, 1, 0), (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # (KV, rep, hd)
    acc_scr[...] = acc_scr[...] * alpha + pv.reshape(H, hd)  # tuna: ignore[TUNA004] same rescale
    m_scr[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, page_table, lengths,
                           interpret: bool = False):
    """q (B,H,hd); k_pages/v_pages (P, page_size, KV, hd);
    page_table (B, ppseq) int32 (-1 = hole); lengths (B,) int32."""
    B, H, hd = q.shape
    P, page_size, KV, _ = k_pages.shape
    ppseq = page_table.shape[1]
    sm_scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(
        _paged_kernel, page_size=page_size, sm_scale=sm_scale
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, ppseq),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j, tbl, ln: (b, 0, 0)),
            pl.BlockSpec(
                (1, page_size, KV, hd),
                lambda b, j, tbl, ln: (jnp.maximum(tbl[b, j], 0), 0, 0, 0),
            ),
            pl.BlockSpec(
                (1, page_size, KV, hd),
                lambda b, j, tbl, ln: (jnp.maximum(tbl[b, j], 0), 0, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, j, tbl, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)
