"""End-to-end trainer: loss descends, failures retried, resume is exact."""

import numpy as np
import pytest

from repro.checkpoint.store import save_checkpoint
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.trainer import train


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("qwen3-1.7b").scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
    )


def test_loss_descends_with_injected_failure(tiny_cfg, tmp_path_factory):
    mesh = make_host_mesh()
    rep = train(
        tiny_cfg, mesh, steps=25, global_batch=4, seq_len=32,
        ckpt_dir=None, inject_failure_at=5,
    )
    assert len(rep.losses) == 25
    assert rep.final_loss < rep.losses[0]
    assert np.isfinite(rep.losses).all()


def test_checkpoint_resume_bit_exact(tiny_cfg, tmp_path):
    mesh = make_host_mesh()
    # full run: 12 steps
    full = train(tiny_cfg, mesh, steps=12, global_batch=4, seq_len=32)
    # interrupted run: 8 steps with a checkpoint at 8, then resume to 12
    train(
        tiny_cfg, mesh, steps=8, global_batch=4, seq_len=32,
        ckpt_dir=tmp_path, ckpt_every=8,
    )
    resumed = train(
        tiny_cfg, mesh, steps=12, global_batch=4, seq_len=32,
        ckpt_dir=tmp_path, ckpt_every=100,
    )
    assert resumed.resumed_from == 8
    # the resumed trajectory must match the uninterrupted run exactly
    np.testing.assert_allclose(
        resumed.losses, full.losses[8:], rtol=1e-5, atol=1e-6
    )


def test_commit_marker_is_deterministic(tmp_path):
    # the same tree at the same step must produce a byte-identical
    # checkpoint directory, COMMIT marker included — a wall-clock payload
    # there would break checkpoint-level reproducibility comparisons
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.zeros(4, dtype=np.float32)}
    a = save_checkpoint(tmp_path / "a", step=7, tree=tree)
    b = save_checkpoint(tmp_path / "b", step=7, tree=tree)
    assert (a / "COMMIT").read_bytes() == (b / "COMMIT").read_bytes()
    payload = (a / "COMMIT").read_text()
    assert '"step": 7' in payload and "manifest_sha256" in payload
