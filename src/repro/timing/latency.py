"""Timing parameters: HardwareProfile -> per-event latency/occupancy knobs.

The timing engine charges each memory *event* two separable costs:

* **occupancy** — the seconds of tier-channel time its bytes consume
  (``access_bytes / bw``); events on one tier serialize through that
  tier's channel (the ``avail_cycle`` model);
* **latency** — the seconds between the channel accepting the event and
  its data arriving; latency is overlapped across the bounded in-flight
  window but is exposed along per-page dependence chains.

Writes resolve through the asymmetric write-path fields of
:class:`repro.sim.costmodel.HardwareProfile` when set (``lat_fast_write``
/ ``lat_slow_write`` / ``bw_slow_write``), else fall back to the read
path. Calibration scales (see :mod:`repro.timing.calibrate`) multiply
latencies and divide occupancies so the engine agrees with the analytic
best case on even-spread microbenchmark streams.

This module also carries the engine's own LLC absorption front-end
(:func:`absorb_llc`), mirroring the ``llc_pages`` semantics of the
interval model's front-end without importing the simulator: the hottest
``llc_pages`` pages per interval cost at most one cold fetch per cache
line, whichever tier backs them.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.sim.costmodel import HardwareProfile

FAST = 0
SLOW = 1


@dataclass(frozen=True)
class TimingParams:
    """Resolved per-tier event costs plus the replay discretization knobs.

    ``lat_rd``/``lat_wr`` are (fast, slow) per-access latencies in
    seconds; ``occ_rd``/``occ_wr`` are (fast, slow) channel seconds per
    cache line. ``window`` is the per-thread in-flight budget (the MLP
    bound); the replay multiplies it by the trace's thread count.
    ``max_events`` bounds the events materialized per interval — larger
    intervals are replayed at a coarser, deterministically chosen
    granularity (see :class:`repro.timing.engine.AddressTimingEngine`).
    """

    lat_rd: tuple[float, float]
    lat_wr: tuple[float, float]
    occ_rd: tuple[float, float]
    occ_wr: tuple[float, float]
    window: float  # in-flight accesses per thread (hw.mlp)
    page_bytes: int
    access_bytes: int
    llc_pages: int
    ops_per_s: float
    migrate_page_overhead: float
    direct_reclaim_stall: float
    promote_fail_penalty: float
    max_events: int = 50_000

    @classmethod
    def from_profile(
        cls,
        hw: HardwareProfile,
        calibration=None,
        max_events: int = 50_000,
    ) -> "TimingParams":
        lat_rd = (hw.lat_fast, hw.lat_slow)
        lat_wr = (
            hw.lat_fast_write if hw.lat_fast_write is not None else hw.lat_fast,
            hw.lat_slow_write if hw.lat_slow_write is not None else hw.lat_slow,
        )
        bw_rd = (hw.bw_fast, hw.bw_slow)
        bw_wr = (
            hw.bw_fast,  # DRAM-class fast tiers are read/write symmetric
            hw.bw_slow_write if hw.bw_slow_write is not None else hw.bw_slow,
        )
        ls = (1.0, 1.0)
        bs = (1.0, 1.0)
        if calibration is not None:
            ls = (calibration.lat_scale_fast, calibration.lat_scale_slow)
            bs = (calibration.bw_scale_fast, calibration.bw_scale_slow)
        return cls(
            lat_rd=(lat_rd[0] * ls[0], lat_rd[1] * ls[1]),
            lat_wr=(lat_wr[0] * ls[0], lat_wr[1] * ls[1]),
            occ_rd=(
                hw.access_bytes / (bw_rd[0] * bs[0]),
                hw.access_bytes / (bw_rd[1] * bs[1]),
            ),
            occ_wr=(
                hw.access_bytes / (bw_wr[0] * bs[0]),
                hw.access_bytes / (bw_wr[1] * bs[1]),
            ),
            window=float(hw.mlp),
            page_bytes=hw.page_bytes,
            access_bytes=hw.access_bytes,
            llc_pages=hw.llc_pages,
            ops_per_s=hw.ops_per_s,
            migrate_page_overhead=hw.migrate_page_overhead,
            direct_reclaim_stall=hw.direct_reclaim_stall,
            promote_fail_penalty=hw.promote_fail_penalty,
            max_events=int(max_events),
        )

    def migration_channel_seconds(self, pm_pr: int, pm_de: int) -> tuple[float, float]:
        """Channel occupancy a batch of migrations preloads on each tier.

        A promotion reads ``page_bytes`` from slow and writes them to
        fast; a demotion reads fast and writes slow — both compete with
        the application's events for the tier channels (the paper's
        characterization #1).
        """
        per_line_pages = self.page_bytes / self.access_bytes
        fast = per_line_pages * (pm_pr * self.occ_wr[FAST] + pm_de * self.occ_rd[FAST])
        slow = per_line_pages * (pm_pr * self.occ_rd[SLOW] + pm_de * self.occ_wr[SLOW])
        return float(fast), float(slow)


def absorb_llc(
    counts: np.ndarray, llc_pages: int, cl_per_page: int = 64
) -> np.ndarray:
    """Cap the hottest ``llc_pages`` pages at one cold fetch per line.

    The timing engine's own cache front-end: same observable semantics as
    the interval model's ``llc_pages`` knob (a page hammered within an
    interval is LLC-resident; its re-references never reach memory),
    implemented here independently so the two clocks share no simulator
    code.
    """
    if llc_pages <= 0:
        return counts
    if counts.size <= llc_pages:
        return np.minimum(counts, cl_per_page)
    kth = np.partition(counts, counts.size - llc_pages)[counts.size - llc_pages]
    out = counts.copy()
    hot = counts >= kth
    out[hot] = np.minimum(counts[hot], cl_per_page)
    return out
