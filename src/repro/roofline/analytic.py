"""Exact analytic FLOP / HBM-byte accounting per (config × shape).

``compiled.cost_analysis()`` on the host backend counts ``while`` bodies
once (the scan-over-layers body!), so the compute/memory roofline terms are
derived analytically from the architecture arithmetic instead — matmul-
exact for every block type, with the remat and train multipliers applied
explicitly. The compiled artifact still gates shardability and provides
the collective traffic (post-SPMD HLO), which the analytic model cannot
know. Raw cost_analysis numbers are kept in the dry-run records for
reference.
"""

from __future__ import annotations

import math

from repro.models.config import ModelConfig


def _attn_proj_flops_per_tok(cfg: ModelConfig) -> float:
    if cfg.attn_type == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        f = (
            cfg.d_model * cfg.q_lora_rank
            + cfg.q_lora_rank * cfg.num_heads * qk
            + cfg.d_model * (cfg.kv_lora_rank + cfg.qk_rope_dim)
            + cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
            + cfg.num_heads * cfg.v_head_dim * cfg.d_model
        )
        return 2.0 * f
    f = cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * cfg.d_model
    return 2.0 * f


def _attn_score_flops_per_tok(cfg: ModelConfig, kv_len: float) -> float:
    """QKᵀ + PV per token attending over kv_len keys."""
    if cfg.attn_type == "mla":
        # latent-space attention: scores vs kv_lora (+rope), values in latent
        d_eff = cfg.kv_lora_rank + cfg.qk_rope_dim + cfg.kv_lora_rank
        return 2.0 * cfg.num_heads * kv_len * d_eff
    return 4.0 * cfg.num_heads * kv_len * cfg.head_dim


def _ffn_flops_per_tok(cfg: ModelConfig, pos: int, capacity_factor=1.25) -> float:
    moe = (
        cfg.n_experts > 0 and pos % cfg.moe_every == cfg.moe_offset
    )
    nmat = 3 if cfg.mlp_act == "swiglu" else 2
    if not moe:
        return 2.0 * nmat * cfg.d_model * cfg.d_ff
    f = 2.0 * nmat * cfg.d_model * (cfg.moe_d_ff or cfg.d_ff)
    tot = f * cfg.top_k * capacity_factor  # dispatched (incl. capacity pad)
    if cfg.n_shared_experts:
        tot += f * cfg.n_shared_experts
    tot += 2.0 * cfg.d_model * cfg.n_experts  # router
    return tot


def _mixer_flops_per_tok(cfg: ModelConfig, kind: str, kv_len: float) -> float:
    if kind == "attn":
        return _attn_proj_flops_per_tok(cfg) + _attn_score_flops_per_tok(cfg, kv_len)
    if kind == "mamba":
        DI, DS = cfg.d_inner, cfg.mamba_d_state
        R = max(1, math.ceil(cfg.d_model / 16))
        return 2.0 * (
            cfg.d_model * 2 * DI
            + cfg.mamba_d_conv * DI
            + DI * (R + 2 * DS)
            + R * DI
            + 4 * DI * DS  # ssm scan work
            + DI * cfg.d_model
        )
    if kind == "rwkv":
        D = cfg.d_model
        chunk = 64.0
        wkv = 2.0 * 2.0 * chunk * D  # intra-chunk A@ and @v per token
        lora = max(32, D // 32)
        return 2.0 * (5 * D * D + 2 * D * lora) + wkv + 2.0 * (
            D * cfg.d_ff + cfg.d_ff * D + D * D
        )
    raise ValueError(kind)


def forward_flops(cfg: ModelConfig, n_tokens: float, kv_len: float,
                  batch: float = 1.0) -> float:
    """One forward pass, all layers + head, for n_tokens each seeing
    kv_len context (kv_len = S/2 average for causal training). ``batch``
    sizes the encoder pass for enc-dec archs (frontend_len frames per
    sequence)."""
    per_tok = 0.0
    for g in range(cfg.num_groups):
        for i, kind in enumerate(cfg.block_pattern):
            per_tok += _mixer_flops_per_tok(cfg, kind, kv_len)
            if kind != "rwkv":
                per_tok += _ffn_flops_per_tok(cfg, i)
    per_tok += 2.0 * cfg.d_model * cfg.vocab_size  # head
    total = per_tok * n_tokens
    if cfg.has_encoder:
        # encoder runs once per sequence over frontend_len frames
        enc_per_tok = cfg.encoder_layers * (
            _attn_proj_flops_per_tok(cfg)
            + _attn_score_flops_per_tok(cfg, cfg.frontend_len)
            + 2.0 * 2 * cfg.d_model * cfg.d_ff
        )
        total += enc_per_tok * cfg.frontend_len * batch
        # cross attention for decoder tokens
        total += n_tokens * cfg.num_layers * (
            2.0 * cfg.d_model * cfg.q_dim * 2
            + _attn_score_flops_per_tok(cfg, cfg.frontend_len)
        )
    return total


_REMAT_FW = {"none": 0.0, "dots": 0.5, "full": 1.0}


def cell_flops(cfg: ModelConfig, kind: str, batch: int, seq: int,
               remat: str = "full") -> float:
    """Total HLO-equivalent FLOPs of one step of the cell."""
    if kind == "train":
        fw = forward_flops(cfg, batch * seq, kv_len=seq / 2, batch=batch)
        return fw * (3.0 + _REMAT_FW.get(remat, 1.0))  # fw + 2x bw + remat
    if kind == "prefill":
        return forward_flops(cfg, batch * seq, kv_len=seq / 2, batch=batch)
    # decode: one token per sequence, attending over the full cache;
    # enc-dec archs re-read only the cross cache (encoder already ran)
    return forward_flops(cfg, batch * 1, kv_len=seq, batch=0.0)


def param_bytes(cfg: ModelConfig, n_params: int) -> float:
    return float(n_params) * 2.0  # bf16


def cell_hbm_bytes(cfg: ModelConfig, kind: str, batch: int, seq: int,
                   n_params: int, remat: str = "full",
                   opt_bytes_per_param: float = 8.0) -> float:
    """HBM traffic of one step (global, all chips): weight reads, optimizer
    update traffic, activation reads/writes, and (for decode) the KV/state
    cache sweep — the decode-dominant term."""
    pb = param_bytes(cfg, n_params)
    act_per_tok_layer = 12.0 * cfg.d_model * 2.0  # reads+writes, bf16
    n_attn = sum(1 for k in cfg.block_pattern if k == "attn") * cfg.num_groups
    if kind == "train":
        reads = pb * (2.0 + _REMAT_FW.get(remat, 1.0))  # fw + bw + remat
        grads = pb * 2.0
        opt = n_params * opt_bytes_per_param * 2.0 + pb * 2.0
        acts = act_per_tok_layer * cfg.num_layers * batch * seq * 2.0
        return reads + grads + opt + acts
    if kind == "prefill":
        return pb + act_per_tok_layer * cfg.num_layers * batch * seq
    # decode
    kv_bytes = 1.0 + 2.0 / 128 if cfg.kv_cache_dtype == "int8" else 2.0
    if cfg.attn_type == "mla":
        kv_per_tok_layer = (cfg.kv_lora_rank + cfg.qk_rope_dim) * kv_bytes
    else:
        kv_per_tok_layer = 2.0 * cfg.kv_dim * kv_bytes
    cache = kv_per_tok_layer * n_attn * batch * seq
    # SSM/RWKV states are O(1) per layer
    state = 0.0
    for kind_ in cfg.block_pattern:
        if kind_ == "mamba":
            state += cfg.d_inner * cfg.mamba_d_state * 4.0 * 2
        if kind_ == "rwkv":
            H = cfg.d_model // cfg.rwkv_head_dim
            state += H * cfg.rwkv_head_dim ** 2 * 4.0 * 2
    state *= cfg.num_groups * batch
    return pb + cache + state + act_per_tok_layer * cfg.num_layers * batch
